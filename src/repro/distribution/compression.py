"""Gradient compression: int8 all-reduce with error feedback.

Distributed-optimization trick for bandwidth-bound data parallelism: each
rank quantizes its local gradient to int8 with a per-block scale, the
all-reduce runs on int8 payloads (4x less ICI traffic than f32, 2x less
than bf16), and the quantization error is fed back into the next step's
gradient (error-feedback / EF-SGD, Seide et al. 2014; 1-bit Adam lineage).

Usage is explicit-SPMD (shard_map over the data axis) because the sync must
be visible to quantize around it — pjit's implicit gradient all-reduce
cannot be intercepted. Intended for pure-DP segments (e.g. the pod axis);
tested in tests/test_distribution.py with forced host devices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _block_scales(x: jnp.ndarray, block: int) -> tuple[jnp.ndarray, ...]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = -(-n // block) * block - n
    flat = jnp.pad(flat, (0, npad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    return blocks, scale, n


def quantize_int8(x: jnp.ndarray, block: int = 256):
    """x -> (int8 blocks (nb, block), f32 scales (nb, 1), orig_len)."""
    blocks, scale, n = _block_scales(x.astype(jnp.float32), block)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, n: int,
                    shape: tuple) -> jnp.ndarray:
    x = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return x.reshape(shape)


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    err: jnp.ndarray | None = None,
                    block: int = 256) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 psum over `axis_name` (inside shard_map).

    Returns (mean gradient, new error-feedback residual). The int8 payload
    is psum'd as int32 (exact — no overflow for <= 2^23 ranks), scales are
    psum'd alongside; decode uses the max scale so the result is a true
    bound-preserving estimate.
    """
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err
    q, scale, n = quantize_int8(xf, block)
    local = dequantize_int8(q, scale, n, x.shape)
    new_err = xf - local
    q_sum = jax.lax.psum(q.astype(jnp.int32) * scale, axis_name)
    n_ranks = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = (q_sum.reshape(-1)[:n] / n_ranks).reshape(x.shape)
    return mean, new_err


def compressed_grad_sync(grads, axis_name: str, err_state=None,
                         block: int = 256):
    """Tree-wise error-feedback int8 gradient mean over a DP axis."""
    if err_state is None:
        err_state = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)
    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [compressed_psum(g, axis_name, e, block)
           for g, e in zip(flat_g, flat_e)]
    synced = jax.tree.unflatten(tree, [o[0] for o in out])
    new_err = jax.tree.unflatten(tree, [o[1] for o in out])
    return synced, new_err
