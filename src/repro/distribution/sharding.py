"""Sharding rules: DP / TP / EP / SP (+ pod-level DP) as PartitionSpecs.

Path-based rules over plain-dict param trees. Conventions:

  * mesh axes: ("data", "model") single-pod, ("pod", "data", "model")
    multi-pod; `pod` is pure data parallelism.
  * TP (model axis): attention QKV/O and MLP in/out projections Megatron
    style; embedding/vocab sharded on the vocab dim.
  * EP: expert dim sharded over `model` when divisible (arctic 128/16),
    otherwise TP inside experts (mixtral 8 experts -> shard d_ff).
  * ZeRO-1: optimizer moments additionally sharded over `data` on the first
    dim that is not already sharded (GSPMD then emits reduce-scatter /
    all-gather pairs around the update instead of full all-reduce).
  * KV caches: batch over (pod, data) when divisible, else sequence over
    (pod, data) (long_500k, global_batch=1); kv-head dim over `model` when
    divisible, else head_dim.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig


def _dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _tp(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _dp(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def _div(n: int, d: int) -> bool:
    return n % d == 0


def _path_str(path) -> str:
    return "/".join(
        p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
        for p in path)


def param_pspec(path_s: str, shape: tuple, cfg: ModelConfig,
                tp: int, n_data: int = 0) -> P:
    """PartitionSpec for one parameter leaf (layer-stacked leaves have a
    leading L dim which is never sharded)."""
    nd = len(shape)

    def last_if(divisible_dim: int):
        """Shard last dim over model if divisible, else replicate."""
        return P(*([None] * (nd - 1) + ["model"])) \
            if _div(shape[divisible_dim], tp) else P()

    def dim_spec(dim: int):
        spec = [None] * nd
        spec[dim] = "model"
        return P(*spec) if _div(shape[dim], tp) else P()

    # embeddings
    if path_s.endswith("embed/table"):
        return dim_spec(0)                       # vocab sharded
    if path_s.endswith("lm_head/w"):
        return dim_spec(nd - 1)                  # vocab sharded
    # norms, biases, scalars, token-shift mixes: replicate
    if any(k in path_s for k in ("ln", "norm", "scale", "bias", "mix_",
                                 "cmix", "d_skip", "a_log", "/u")):
        return P()
    # MoE
    if "moe/router" in path_s:
        return P()
    if "/moe/" in path_s:                        # (L, E, D, F) or (L, E, F, D)
        f_dim = 3 if path_s.endswith(("wi", "wg")) else 2
        if n_data and _div(shape[1], n_data) and _div(shape[f_dim], tp):
            # 2-D expert sharding: EP over data + TP over model — the
            # dispatch buffers reshard (B->E) with a small all-to-all
            # instead of FSDP-gathering the expert weights every step
            spec = [None] * nd
            spec[1] = "data"
            spec[f_dim] = "model"
            return P(*spec)
        if _div(shape[1], tp):
            return P(None, "model")              # EP over model
        # TP inside experts: shard the F dim (wi/wg: last; wo: dim 2)
        return dim_spec(f_dim)
    # column-parallel (output dim sharded)
    if path_s.endswith(("wq", "wk", "wv", "wi", "wg", "in_proj", "bc_proj",
                        "dt_proj", "wr", "ck", "cr", "w_proj", "conv_w")):
        return dim_spec(nd - 1)
    # row-parallel (input dim sharded)
    if path_s.endswith(("wo", "out_proj", "cv")):
        return dim_spec(nd - 2)
    return P()


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_tree: Any):
    """Tree of NamedShardings matching `params_tree` (arrays or SDS).

    cfg.fsdp=True additionally shards every large leaf over `data` on its
    first free dim (ZeRO-3: XLA all-gathers each layer's weights at use
    inside the layer scan; required to fit 110B-param training state on
    16 GB v5e HBM — see EXPERIMENTS.md §Perf qwen iterations)."""
    tp = _tp(mesh)
    n_data = mesh.shape["data"]

    def spec(path, leaf):
        base = param_pspec(_path_str(path), leaf.shape, cfg, tp,
                           n_data=n_data)
        flat = [ax for ax in jax.tree.leaves(tuple(base))]
        if cfg.fsdp and leaf.size >= 1 << 20 and "data" not in flat:
            specs = list(base) + [None] * (len(leaf.shape) - len(base))
            for i, (dim, cur) in enumerate(zip(leaf.shape, specs)):
                if cur is None and dim % n_data == 0 and dim >= n_data:
                    specs[i] = "data"
                    break
            base = P(*specs)
        return NamedSharding(mesh, base)

    return jax.tree_util.tree_map_with_path(spec, params_tree)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_tree: Any):
    """Batch dims over (pod, data); everything else replicated."""
    dp = _dp_axes(mesh)
    n_dp = _dp(mesh)

    def spec(path, leaf):
        b = leaf.shape[0] if leaf.shape else 0
        if b and _div(b, n_dp):
            return NamedSharding(mesh,
                                 P(dp, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_tree: Any):
    """KV/state caches: (L, B, H, S, hd) and friends."""
    dp = _dp_axes(mesh)
    n_dp = _dp(mesh)
    tp = _tp(mesh)

    def spec(path, leaf):
        s = leaf.shape
        p = _path_str(path)
        if not s:                                 # pos scalar
            return NamedSharding(mesh, P())
        if p.endswith(("k", "v", "xk", "xv")) and len(s) == 5:
            L, B, H, S, hd = s
            batch_ax = dp if _div(B, n_dp) else None
            head_ax = "model" if _div(H, tp) else None
            # heads not TP-divisible: shard the sequence over model instead
            # (decode softmax then needs only tiny max/sum collectives,
            # vs per-layer full-logit all-reduces for head_dim sharding)
            seq_ax = None
            if head_ax is None:
                if batch_ax is None and _div(S, n_dp * tp):
                    seq_ax = dp + ("model",)
                elif _div(S, tp):
                    seq_ax = "model"
            return NamedSharding(mesh, P(None, batch_ax, head_ax, seq_ax,
                                         None))
        if p.endswith(("k_scale", "v_scale")) and len(s) == 4:
            # (L, B, H, S) int8-KV scales: mirror the 5-D cache sharding
            L, B, H, S = s
            batch_ax = dp if _div(B, n_dp) else None
            head_ax = "model" if _div(H, tp) else None
            seq_ax = None
            if head_ax is None:
                if batch_ax is None and _div(S, n_dp * tp):
                    seq_ax = dp + ("model",)
                elif _div(S, tp):
                    seq_ax = "model"
            return NamedSharding(mesh, P(None, batch_ax, head_ax, seq_ax))
        if p.endswith("wkv") and len(s) == 5:     # (L, B, H, dk, dv)
            L, B, H, dk, dv = s
            batch_ax = dp if _div(B, n_dp) else None
            head_ax = "model" if _div(H, tp) else None
            return NamedSharding(mesh, P(None, batch_ax, head_ax, None,
                                         None))
        if p.endswith("ssm_state") and len(s) == 4:  # (L, B, Din, N)
            L, B, Din, N = s
            batch_ax = dp if _div(B, n_dp) else None
            ch_ax = "model" if _div(Din, tp) else None
            return NamedSharding(mesh, P(None, batch_ax, ch_ax, None))
        if len(s) >= 2:                           # conv / last_* caches
            B = s[1]
            batch_ax = dp if _div(B, n_dp) else None
            return NamedSharding(mesh,
                                 P(None, batch_ax,
                                   *([None] * (len(s) - 2))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def zero1_shardings(cfg: ModelConfig, mesh: Mesh, params_tree: Any):
    """Optimizer-moment shardings: param spec + `data` on the first free dim.

    This is ZeRO-1 expressed in GSPMD: states sharded over data parallel
    ranks; XLA turns the gradient all-reduce + update into
    reduce-scatter + local update + all-gather of the new params.
    """
    tp = _tp(mesh)
    n_data = mesh.shape["data"]

    def spec(path, leaf):
        base = param_pspec(_path_str(path), leaf.shape, cfg, tp)
        specs = list(base) + [None] * (len(leaf.shape) - len(base))
        for i, (dim, cur) in enumerate(zip(leaf.shape, specs)):
            if cur is None and dim % n_data == 0 and dim >= n_data:
                specs[i] = "data"
                break
        return NamedSharding(mesh, P(*specs))

    return jax.tree_util.tree_map_with_path(spec, params_tree)


def replicated(mesh: Mesh, tree: Any):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
