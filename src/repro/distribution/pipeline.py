"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Layers are split into P contiguous stages along a `pipe` mesh axis; M
microbatches stream through the stages with the canonical (P + M - 1)-step
schedule. Each step, every device applies its stage to its current
microbatch and the activations rotate one stage forward via ppermute —
the static, compile-time-known communication pattern of the paper's
management core, expressed on the ICI.

Bubble fraction = (P - 1) / (M + P - 1); amortize with M >> P.
Used as an optional parallelism mode (train over `pipe` axis) and as a
§Perf hillclimb candidate; validated in tests/test_distribution.py against
the sequential reference on forced host devices.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(mesh, layer_fn: Callable, stage_params, x_micro,
                   axis: str = "pipe"):
    """Run microbatches through pipeline stages.

    layer_fn(params_one_layer, x) -> x        (applied over a stage's
                                               layers with lax.scan)
    stage_params: pytree with leading dim (P, layers_per_stage, ...)
                  sharded so each pipe rank holds its (1, Lp, ...) slice.
    x_micro: (M, mb, ...) microbatched input, replicated across `pipe`.
    Returns (M, mb, ...) outputs (as produced by the last stage).
    """
    Pn = mesh.shape[axis]
    M = x_micro.shape[0]
    steps = Pn + M - 1

    def stage_apply(params, x):
        def body(h, pl_):
            return layer_fn(pl_, h), None
        out, _ = jax.lax.scan(body, x, params)
        return out

    def per_device(params, xs):
        # params: (1, Lp, ...) this rank's stage;  xs: (M, mb, ...)
        params = jax.tree.map(lambda a: a[0], params)
        rank = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)          # current activation
        outs = jnp.zeros_like(xs)                    # stage-P outputs

        def step(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            take = jnp.clip(t, 0, M - 1)
            fresh = jax.lax.dynamic_index_in_dim(xs, take, 0, False)
            h = jnp.where(rank == 0, fresh, buf)
            active = (t - rank >= 0) & (t - rank < M)
            y = jnp.where(active, stage_apply(params, h), h)
            # last stage emits microbatch (t - P + 1)
            emit_idx = jnp.clip(t - Pn + 1, 0, M - 1)
            emit = (rank == Pn - 1) & (t - Pn + 1 >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, emit_idx, 0),
                lambda o: o, outs)
            # rotate activations one stage forward
            perm = [(i, (i + 1) % Pn) for i in range(Pn)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(step, (buf, outs), jnp.arange(steps))
        # every rank holds zeros except the last; share results
        outs = jax.lax.psum(outs, axis)
        return outs

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params),
                P())                                  # xs replicated
    fn = shard_map(per_device, mesh=mesh, in_specs=in_specs,
                   out_specs=P(), check_rep=False)
    return fn(stage_params, x_micro)


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (P, L/P, ...)."""
    def r(a):
        L = a.shape[0]
        assert L % n_stages == 0, f"{L} layers not divisible by {n_stages}"
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])
    return jax.tree.map(r, stacked_params)
