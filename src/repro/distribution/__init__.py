"""Distribution layer: sharding rules, pipeline parallelism, gradient
compression, mesh context."""

from .sharding import (batch_shardings, cache_shardings, param_pspec,
                       param_shardings, replicated, zero1_shardings)
from .pipeline import pipeline_apply, split_stages
from .compression import compressed_grad_sync, compressed_psum
from .context import current_mesh, with_mesh_context

__all__ = ["batch_shardings", "cache_shardings", "param_pspec",
           "param_shardings", "replicated", "zero1_shardings",
           "pipeline_apply", "split_stages", "compressed_grad_sync",
           "compressed_psum", "current_mesh", "with_mesh_context"]
