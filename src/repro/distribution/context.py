"""Mesh context for model-internal SPMD decisions.

Model code (e.g. the MoE dispatch shard_map) needs the mesh at trace time;
`jax.sharding.get_abstract_mesh()` is only populated in explicit-axes mode,
so launchers wrap lowering/execution in `with_mesh_context(mesh)` and model
code asks `current_mesh()` (which also falls back to the abstract mesh when
present)."""

from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()


@contextlib.contextmanager
def with_mesh_context(mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def current_mesh():
    m = getattr(_state, "mesh", None)
    if m is not None:
        return m
    # only on newer jax; older versions have no trace-time abstract mesh,
    # so the explicit with_mesh_context above is the only source there
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        am = get_abstract()
        if am is not None and not getattr(am, "empty", True):
            return am
    return None
