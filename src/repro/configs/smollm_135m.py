"""smollm-135m [dense]: llama-arch small, tied embeddings.
30L d_model=576 9H (GQA kv=3, head_dim=64) d_ff=1536 vocab=49152.
[hf:HuggingFaceTB/SmolLM-135M; hf]

Full attention -> long_500k SKIPPED. Also the end-to-end training example
target (~135M params trains on CPU at reduced depth).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
    head_dim=64, d_ff=1536, vocab_size=49152,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="smollm-135m-reduced", family="dense",
    num_layers=3, d_model=192, num_heads=3, num_kv_heads=1,
    head_dim=64, d_ff=512, vocab_size=512,
    tie_embeddings=True,
    dtype="float32", remat="none",
)
