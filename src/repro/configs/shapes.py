"""Assigned input-shape cells and their ShapeDtypeStruct factories.

LM transformer shapes are (seq_len, global_batch). decode_*/long_* lower
`serve_step` (decode: one new token against a seq_len KV cache); prefill
lowers the cache-filling prefill step; train_4k lowers `train_step`.
long_500k needs sub-quadratic attention: only archs with
cfg.subquadratic=True run it (skips recorded per config docstring and
DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.serve import cache_spec


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: long_500k needs sub-quadratic "
                       "attention (skip per task spec; see DESIGN.md)")
    return True, ""


def _sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def enc_len_for(cfg: ModelConfig, cell: ShapeCell) -> int:
    """Encoder length for enc-dec archs (half the cell budget, min 128)."""
    return max(128, cell.seq_len // 4) if cfg.family == "encdec" else 0


def input_specs(cfg: ModelConfig, shape: str,
                scale_batch: float = 1.0) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Returns {"batch": ..., "cache": ...} as applicable; weights/optimizer
    specs are produced separately via jax.eval_shape over init fns.
    scale_batch shrinks global_batch for reduced-scale experiments.
    """
    cell = SHAPES[shape]
    B = max(1, int(cell.global_batch * scale_batch))
    S = cell.seq_len
    dt = cfg.jnp_dtype

    if cell.kind == "train":
        if cfg.family == "encdec":
            s_enc = S // 2
            s_dec = S - s_enc
            batch = {"tokens": _sds((B, s_dec)),
                     "labels": _sds((B, s_dec)),
                     "src_tokens": _sds((B, s_enc)),
                     "frontend_embeds": _sds((B, s_enc, cfg.d_model), dt)}
            if cfg.frontend is None:
                batch.pop("frontend_embeds")
            return {"batch": batch}
        batch = {"tokens": _sds((B, S)), "labels": _sds((B, S))}
        if cfg.frontend is not None and cfg.frontend_tokens:
            batch["frontend_embeds"] = _sds(
                (B, cfg.frontend_tokens, cfg.d_model), dt)
        return {"batch": batch}

    if cell.kind == "prefill":
        enc_len = enc_len_for(cfg, cell)
        if cfg.family == "encdec":
            batch = {"tokens": _sds((B, S)),
                     "src_tokens": _sds((B, enc_len)),
                     "frontend_embeds": _sds((B, enc_len, cfg.d_model), dt)}
            if cfg.frontend is None:
                batch.pop("frontend_embeds")
        else:
            batch = {"tokens": _sds((B, S))}
            if cfg.frontend is not None and cfg.frontend_tokens:
                batch["frontend_embeds"] = _sds(
                    (B, cfg.frontend_tokens, cfg.d_model), dt)
        return {"batch": batch, "cache": cache_spec(cfg, B, S, enc_len)}

    # decode: one new token against a seq_len cache
    enc_len = enc_len_for(cfg, cell)
    return {"tokens": _sds((B, 1)),
            "cache": cache_spec(cfg, B, S, enc_len)}
