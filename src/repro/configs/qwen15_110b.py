"""qwen1.5-110b [dense]: QKV bias, GQA.
80L d_model=8192 64H (kv=8, head_dim=128) d_ff=49152 vocab=152064.
[hf:Qwen/Qwen1.5-110B (family ref hf:Qwen/Qwen1.5-0.5B); hf]

Full attention -> long_500k SKIPPED. Largest dense arch in the pool
(~110B params) — ZeRO-1 optimizer sharding required to fit train state.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=49152, vocab_size=152064,
    qkv_bias=True,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="qwen1.5-110b-reduced", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=384, vocab_size=512,
    qkv_bias=True,
    dtype="float32", remat="none",
)
