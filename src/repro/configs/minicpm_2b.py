"""minicpm-2b [dense]: llama-like arch, trained with the WSD
(warmup-stable-decay) schedule — implemented in repro.train.optimizer.
40L d_model=2304 36H (kv=36 -> MHA, head_dim=64) d_ff=5760 vocab=122753.
[arXiv:2404.06395; hf]

Full attention -> long_500k SKIPPED.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    head_dim=64, d_ff=5760, vocab_size=122753,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="minicpm-2b-reduced", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=512,
    tie_embeddings=True,
    dtype="float32", remat="none",
)

# training-schedule metadata (the arch's distinguishing training feature)
TRAIN_SCHEDULE = "wsd"
