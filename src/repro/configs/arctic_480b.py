"""arctic-480b [moe]: 128 experts top-2 + dense residual MLP.
35L d_model=7168 56H (kv=8, head_dim=128) d_ff=4864 vocab=32000.
[hf:Snowflake/snowflake-arctic-base; hf]

Dense-MoE hybrid: a small always-on dense MLP runs in parallel ("residual")
with the routed experts. Capacity-bounded top-2 dispatch keeps every shape
static (paper's fixed-dataflow requirement). Full attention ->
long_500k SKIPPED.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=4864, vocab_size=32000,
    num_experts=128, top_k=2, dense_residual_ff=9216,
    moe_dispatch="sorted",
    capacity_factor=1.25,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="arctic-480b-reduced", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=128, vocab_size=512,
    num_experts=4, top_k=2, dense_residual_ff=64,
    capacity_factor=1.25,
    dtype="float32", remat="none",
)
