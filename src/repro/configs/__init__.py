"""Architecture registry: the 10 assigned archs (+ paper-native CNNs).

Usage:  cfg = get_config("qwen1.5-110b");  red = get_config("qwen1.5-110b",
reduced=True).  `--arch <id>` in launch scripts resolves through here.
"""

from __future__ import annotations

import importlib

from .shapes import SHAPES, ShapeCell, cell_applicable, input_specs, \
    enc_len_for

_ARCH_MODULES = {
    "pixtral-12b": "pixtral_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internlm2-20b": "internlm2_20b",
    "smollm-135m": "smollm_135m",
    "minicpm-2b": "minicpm_2b",
    "qwen1.5-110b": "qwen15_110b",
    "zamba2-1.2b": "zamba2_1p2b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "arctic-480b": "arctic_480b",
    "mixtral-8x22b": "mixtral_8x22b",
}

ARCH_IDS = list(_ARCH_MODULES)

# the paper's own CNN targets (graphs for the predictable-inference pipeline)
PAPER_CNNS = ("resnet50", "yolov5s", "small_cnn")


def get_config(arch_id: str, reduced: bool = False):
    mod = importlib.import_module(
        f".{_ARCH_MODULES[arch_id]}", __package__)
    return mod.REDUCED if reduced else mod.CONFIG


def get_cnn_graph(name: str, **kw):
    from ..core import cnn
    if name == "resnet50":
        return cnn.resnet50(**kw)
    if name == "yolov5s":
        return cnn.yolov5s_backbone(**kw)
    if name == "small_cnn":
        return cnn.small_cnn(**kw)
    raise KeyError(name)


__all__ = ["ARCH_IDS", "PAPER_CNNS", "SHAPES", "ShapeCell", "get_config",
           "get_cnn_graph", "cell_applicable", "input_specs", "enc_len_for"]
