"""rwkv6-1.6b [ssm]: Finch — attention-free, data-dependent per-channel
decay, matrix-valued WKV state.
24L d_model=2048 (32 heads of 64) d_ff=7168 vocab=65536.
[arXiv:2404.05892; unverified]

Attention-free; decode state O(H*dk*dv) independent of context ->
long_500k RUNS. The paper's attention-sharding aspects are inapplicable
(no attention) — noted in DESIGN.md §Arch-applicability; the static
DMA-schedule/WCET pipeline applies unchanged (WKV update is a subtask).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    subquadratic=True,
)

REDUCED = ModelConfig(
    name="rwkv6-1.6b-reduced", family="ssm",
    num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
    d_ff=256, vocab_size=512,
    subquadratic=True,
    dtype="float32", remat="none",
)
