"""seamless-m4t-medium [audio]: encoder-decoder, multimodal.
12L (12 enc + 12 dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
[arXiv:2308.11596; hf]

Audio frontend (w2v-BERT conformer stack) is a STUB per the task spec:
input_specs provide precomputed frame embeddings. Full attention enc-dec ->
long_500k SKIPPED. Decode shapes run (decoder is autoregressive).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=0, enc_layers=12, dec_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    qkv_bias=True, norm="layernorm", act="gelu",
    frontend="audio", frontend_tokens=0,
)

REDUCED = ModelConfig(
    name="seamless-m4t-medium-reduced", family="encdec",
    num_layers=0, enc_layers=2, dec_layers=2,
    d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    qkv_bias=True, norm="layernorm", act="gelu",
    frontend="audio", frontend_tokens=0,
    dtype="float32", remat="none",
)
