"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block.
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
[arXiv:2411.15242; hf]

The single shared attention(+MLP) block is applied every `attn_every`
Mamba2 blocks with tied weights (the Zamba2 design). SSM state is O(1) in
sequence length -> long_500k RUNS (shared-attn KV cache kept for the few
application points only).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=32000,
    ssm_state=64, attn_every=6,
    subquadratic=True,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced", family="hybrid",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=512,
    ssm_state=16, attn_every=2,
    subquadratic=True,
    dtype="float32", remat="none",
)
