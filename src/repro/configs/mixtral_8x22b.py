"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention.
56L d_model=6144 48H (kv=8, head_dim=128) d_ff=16384 vocab=32768.
[arXiv:2401.04088; hf]

SWA (4096) bounds the decode KV working set -> sub-quadratic ->
long_500k RUNS (rolling-window cache; here the static cache keeps max_len
but attention only reads the window — the roofline counts window reads).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=16384, vocab_size=32768,
    num_experts=8, top_k=2, moe_dispatch="sorted",
    sliding_window=4096,
    subquadratic=True,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=128, vocab_size=512,
    num_experts=4, top_k=2,
    sliding_window=8,
    subquadratic=True,
    dtype="float32", remat="none",
)
