"""pixtral-12b [vlm]: Pixtral-ViT frontend (STUB) + Mistral-Nemo backbone.
40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409; unverified]

Full attention (128k-rope Nemo backbone, no sliding window) ->
long_500k SKIPPED (see DESIGN.md §Arch-applicability).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    rope_theta=1e6,
    frontend="vision", frontend_tokens=256,
)

REDUCED = ModelConfig(
    name="pixtral-12b-reduced", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
    head_dim=32, d_ff=256, vocab_size=512,
    rope_theta=1e6,
    frontend="vision", frontend_tokens=4,
    dtype="float32", remat="none",
)
